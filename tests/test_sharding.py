"""Logical-axis sharding rules and the constrain() no-mesh contract."""
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding import constrain, default_rules, logical_to_spec


RULES = {"batch": ("pod", "data"), "embed": "data", "vocab": "model",
         "ff": "model", "seq": None}


def test_logical_to_spec_basic():
    assert logical_to_spec(("batch", "seq", "ff"), RULES) \
        == P(("pod", "data"), None, "model")
    assert logical_to_spec(("vocab", "embed"), RULES) == P("model", "data")


def test_logical_to_spec_no_duplicate_axes():
    """A mesh axis may appear once per spec: later dims fall back to None."""
    spec = logical_to_spec(("vocab", "ff"), RULES)     # both -> 'model'
    assert spec == P("model", None)
    spec2 = logical_to_spec(("batch", "embed"), RULES)  # data used by batch
    assert spec2 == P(("pod", "data"), None)


def test_constrain_identity_without_rules():
    x = jnp.ones((4, 8))
    y = constrain(x, "batch", "ff")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_default_rules_shape():
    import jax
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    r = default_rules(mesh, fsdp=True)
    assert r["batch"] == ("data",)
    assert r["embed"] == "data"
    r2 = default_rules(mesh, fsdp=False)
    assert r2["embed"] is None


def test_constrain_skips_indivisible_dims():
    """24 heads on a 16-way axis: constrain leaves the dim unsharded
    instead of erroring (GSPMD decides)."""
    import jax
    from repro.sharding import use_rules
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    with use_rules(mesh, {"heads": "model"}):
        x = jnp.ones((5, 3))          # 3 % 1 == 0 -> fine either way
        y = constrain(x, None, "heads")
        assert y.shape == x.shape
