"""S-RSVD gradient compression: eligibility, exactness on low-rank
gradients, error feedback, and byte accounting.

Single-device psum semantics (axis size 1) make the compress/decompress
path testable without a multi-device mesh; cross-pod behaviour is covered
in tests/test_distributed.py via subprocess.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim import (CompressConfig, compress_state_init,
                         compressed_pod_mean)
from repro.optim.compress import comm_bytes, leaf_eligible


def _run_pod1(cfg, grads, err, step=0):
    """Run compressed_pod_mean under a 1-device 'pod' mesh."""
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def body(g, e):
        return compressed_pod_mean(cfg, g, e, jnp.asarray(step))

    return jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), grads),
                  jax.tree.map(lambda _: P(), err)),
        out_specs=(jax.tree.map(lambda _: P(), grads),
                   jax.tree.map(lambda _: P(), err))))(grads, err)


def test_eligibility_rules():
    cfg = CompressConfig(rank=8, min_dim=64, min_numel=4096)
    assert leaf_eligible(cfg, jnp.zeros((256, 256)))
    assert not leaf_eligible(cfg, jnp.zeros((256,)))        # 1-D
    assert not leaf_eligible(cfg, jnp.zeros((32, 4096)))    # min_dim
    assert not leaf_eligible(cfg, jnp.zeros((64, 33)))      # numel+4k rank
    assert leaf_eligible(cfg, jnp.zeros((4, 256, 256)))     # stacked ok


def test_exact_recovery_of_low_rank_gradient(rng):
    """A gradient that is exactly rank<=K + rank-1 offset must be
    recovered (near-)exactly by shifted compression."""
    m, n, r = 128, 256, 4
    cfg = CompressConfig(rank=16, min_dim=64, min_numel=1024)
    G = (rng.standard_normal((m, r)) @ rng.standard_normal((r, n))
         + rng.standard_normal((m, 1)))                     # offset rows
    grads = {"w": jnp.asarray(G, jnp.float32)}
    err = compress_state_init(cfg, grads)
    out, new_err = _run_pod1(cfg, grads, err)
    np.testing.assert_allclose(np.asarray(out["w"]), G, atol=1e-3)
    # error feedback ~ 0 for exactly-representable gradients
    assert float(jnp.abs(new_err["w"]).max()) < 1e-3


def test_small_leaves_pass_through_exactly(rng):
    cfg = CompressConfig(rank=8, min_dim=64, min_numel=1 << 20)
    g = {"b": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    err = compress_state_init(cfg, g)
    out, _ = _run_pod1(cfg, g, err)
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(g["b"]),
                               atol=1e-6)


def test_error_feedback_accumulates_residual(rng):
    """err' = g - decompress(compress(g)); the next step's compression of
    (g + err) must recover more of g than step one did."""
    m, n = 128, 256
    cfg = CompressConfig(rank=4, min_dim=64, min_numel=1024)
    G = rng.standard_normal((m, n)).astype(np.float32)      # full-rank
    grads = {"w": jnp.asarray(G)}
    err = compress_state_init(cfg, grads)
    out1, err1 = _run_pod1(cfg, grads, err, step=0)
    # residual is nonzero for full-rank input
    r1 = np.linalg.norm(np.asarray(err1["w"]))
    assert r1 > 0.1
    # accumulated transmission over steps: sum of decompressed means + the
    # leftover error equals the sum of true gradients (EF invariant)
    out2, err2 = _run_pod1(cfg, grads, err1, step=1)
    total_sent = np.asarray(out1["w"]) + np.asarray(out2["w"])
    total_true = 2.0 * G
    leftover = np.asarray(err2["w"])
    np.testing.assert_allclose(total_sent + leftover, total_true,
                               atol=1e-3)


def test_shift_handles_offcenter_better_than_plain(rng):
    """The paper's claim applied to gradients: with a strong row-offset,
    shifted compression has lower residual than unshifted at equal rank."""
    m, n = 128, 512
    G = (0.3 * rng.standard_normal((m, n))
         + 5.0 * rng.standard_normal((m, 1))).astype(np.float32)
    res = {}
    for shift in (True, False):
        cfg = CompressConfig(rank=2, min_dim=64, min_numel=1024,
                             shift=shift)
        grads = {"w": jnp.asarray(G)}
        err = compress_state_init(cfg, grads)
        _, err1 = _run_pod1(cfg, grads, err)
        res[shift] = float(jnp.sum(jnp.square(err1["w"])))
    assert res[True] < res[False]


def test_comm_bytes_accounting():
    cfg = CompressConfig(rank=16, min_dim=64, min_numel=1024)
    tree = {"big": jnp.zeros((1024, 1024)), "small": jnp.zeros((8, 8))}
    acct = comm_bytes(cfg, tree)
    assert acct["plain_bytes"] == 4 * (1024 * 1024 + 64)
    expect_comp = 4 * (16 * (1024 + 1024) + 1024) + 4 * 64
    assert acct["compressed_bytes"] == expect_comp
    assert acct["ratio"] > 25


def test_deterministic_across_pods_same_step(rng):
    """The Gaussian test matrix must depend only on (step, leaf index) —
    the psum-linearity argument requires identical omega on every pod."""
    from repro.optim.compress import srsvd_compress_leaf  # noqa: F401
    # indirectly: two runs at the same step give identical results
    cfg = CompressConfig(rank=8, min_dim=64, min_numel=1024)
    G = rng.standard_normal((128, 256)).astype(np.float32)
    grads = {"w": jnp.asarray(G)}
    err = compress_state_init(cfg, grads)
    a, _ = _run_pod1(cfg, grads, err, step=3)
    b, _ = _run_pod1(cfg, grads, err, step=3)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]))
    c, _ = _run_pod1(cfg, grads, err, step=4)
    assert np.abs(np.asarray(a["w"]) - np.asarray(c["w"])).max() > 1e-6
